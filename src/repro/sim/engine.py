"""The discrete-event serving simulator.

Request lifecycle (all times ms):

    ARRIVAL ── uplink (T_input) ──▶ ENQUEUE ── FIFO wait ──▶ service
            ── inference ──▶ FINISH ── downlink (T_input) ──▶ DEPART

At ENQUEUE the engine hands the request to the unified
``repro.router.Router`` — admission verdict, budget math and model
selection all live there.  Consecutive same-timestamp ENQUEUE events
(plus an optional ``batch_window_ms`` speculative lookahead) are grouped
into ONE ``route_batch`` call, so the event loop rides the vectorized
policy path; a singleton batch takes the scalar ``select_traced`` route,
which is draw-for-draw identical to the historical per-request call —
seeded runs with continuous (never-colliding) event times are
bit-identical to the pre-router engine.  Queue-aware mode presents the
policy with per-model budgets ``T_sla - 2*T_input - W_queue(m)`` via the
router's shifted store view.  The admitted request joins the FIFO of the
least-loaded capable replica, and — exactly like the live serving path —
the profile store receives the *inference* latency at FINISH and the
observed queue wait at service start (telemetry mirroring
``serving/batcher.py``).

Per-request SLAs are first-class: ``run(..., sla_for=...)`` assigns each
request its own ``t_sla_ms`` (heterogeneous mixes become one more column
of the batched budget vector) and attainment is scored per request.

Driven by ``ClosedLoopArrivals`` over a single shared replica this
engine replays the paper's §4 closed loop draw-for-draw —
``core/simulate.Simulator`` is now a thin wrapper around it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy
from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry, make_store, true_profiles
from repro.router import AdmissionController, InferenceRequest, Router
from repro.sim.arrivals import ArrivalProcess, ClosedLoopArrivals
from repro.sim.events import ARRIVAL, DEPART, ENQUEUE, FINISH, EventQueue
from repro.sim.replica import (GaussianServiceModel, Replica, ReplicaPool,
                               shared_replicas)


@dataclass
class SimRequest:
    rid: int
    arrival_ms: float
    t_input_ms: float = 0.0
    t_sla_ms: float = 0.0
    sla_class: str = ""
    model: str = ""
    replica: str = ""
    fallback: bool = False
    rejected: bool = False
    reject_reason: str = ""
    enqueue_ms: float = 0.0
    service_start_ms: float = 0.0
    service_ms: float = 0.0
    finish_ms: float = 0.0
    depart_ms: float = 0.0

    @property
    def queue_wait_ms(self) -> float:
        return self.service_start_ms - self.enqueue_ms

    @property
    def e2e_ms(self) -> float:
        # Component sum (not event-time subtraction): uplink + FIFO wait
        # + inference + downlink.  Bit-identical to the legacy closed
        # loop's ``2*T_input + T_inf`` at zero queue wait.
        return 2.0 * self.t_input_ms + self.queue_wait_ms + self.service_ms


@dataclass
class LoadSimResult:
    policy: str
    t_sla: float
    n_arrived: int
    n_completed: int
    n_rejected: int
    sla_attainment: float        # met / arrived (rejections are misses)
    mean_accuracy: float         # over completed requests
    mean_latency: float          # e2e ms over completed
    p50_latency: float
    p99_latency: float
    mean_queue_wait: float
    p99_queue_wait: float
    peak_queue_depth: int
    model_usage: Dict[str, float]          # fraction of completed
    replica_utilization: Dict[str, float]  # busy time / horizon
    horizon_ms: float = 0.0
    # Per-SLA-class slice (populated when any request carried a class
    # label): class -> {n_arrived, n_rejected, attainment, accuracy,
    # shed_rate, mean_latency}.  Attainment counts rejections as misses,
    # exactly like the run-level number.
    per_class: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def violation_rate(self) -> float:
        return 1.0 - self.sla_attainment


class ServingSimulator:
    """Event-driven serving over a pool of heterogeneous replicas."""

    def __init__(self, entries: Sequence[ZooEntry], network: NetworkModel,
                 replicas: Optional[Union[ReplicaPool, List[Replica]]] = None,
                 *, seed: int = 0, alpha: float = 0.1, cold_age: int = 500,
                 cold_probe: bool = True, spike_prob: float = 0.0,
                 spike_mult: float = 10.0, queue_aware: bool = False,
                 admission: Optional[AdmissionController] = None,
                 batch_window_ms: float = 0.0,
                 backend: Optional[str] = None):
        self.entries = list(entries)
        self.network = network
        if replicas is None:
            replicas = shared_replicas(1)
        self.pool = (replicas if isinstance(replicas, ReplicaPool)
                     else ReplicaPool(replicas))
        self.seed = seed
        self.alpha = alpha
        self.cold_age = cold_age
        self.cold_probe = cold_probe
        self.spike_prob = spike_prob
        self.spike_mult = spike_mult
        self.queue_aware = queue_aware
        self.admission = admission
        # policy_vec backend override for batched route_batch selection
        self.backend = backend
        # Speculative lookahead for route_batch grouping: consecutive
        # ENQUEUE events within this window of the first one are routed
        # together against one queue snapshot.  0.0 batches only exact
        # timestamp ties (simultaneous arrivals), which keeps runs with
        # continuous event times bit-identical to per-request routing.
        self.batch_window_ms = batch_window_ms
        self.router: Optional[Router] = None  # built per run()

    @classmethod
    def from_scenario(cls, scenario, *,
                      n_replicas: Optional[int] = None) -> "ServingSimulator":
        """Adapter: build an engine from a declarative
        :class:`repro.scenario.Scenario` (``n_replicas`` overrides the
        deployment's replica count — the autoscaler knob)."""
        from repro.scenario.build import build_engine
        return build_engine(scenario, n_replicas=n_replicas)

    # ------------------------------------------------------------------
    def run(self, policy: Policy, t_sla: float,
            n_requests: int = 10_000,
            arrivals: Optional[ArrivalProcess] = None,
            warm: bool = True,
            store: Optional[ProfileStore] = None,
            sla_for: Optional[Callable[[int], float]] = None,
            class_for: Optional[Callable[[int], str]] = None
            ) -> LoadSimResult:
        """Simulate ``n_requests``.  ``sla_for(rid)`` (optional) assigns
        per-request SLAs; ``t_sla`` remains the reporting label and the
        default for requests without an override.  ``class_for(rid)``
        (optional) labels each request with an SLA class — the label
        rides ``InferenceRequest.sla_class`` into class-aware admission
        and slices the summary's ``per_class`` rows; it never touches
        the RNG, so labelled runs stay draw-for-draw identical to
        unlabelled ones under the same seed."""
        arrivals = arrivals or ClosedLoopArrivals()
        rng = np.random.default_rng(self.seed)
        store = store or make_store(self.entries, alpha=self.alpha,
                                    cold_age=self.cold_age, warm=warm)
        truth = true_profiles(self.entries)
        svc = GaussianServiceModel(truth, spike_prob=self.spike_prob,
                                   spike_mult=self.spike_mult)
        # trace_detail=False: the event loop consumes only variant +
        # fallback, so batched decisions skip stage-tuple materialization.
        router = Router(store, policy, admission=self.admission,
                        queue_aware=self.queue_aware, backend=self.backend,
                        trace_detail=False)
        self.router = router
        self.pool.reset()

        evq = EventQueue()
        completed: List[SimRequest] = []
        rejected: List[SimRequest] = []
        n_issued = 0
        if n_requests > 0:
            evq.push(arrivals.first(rng), ARRIVAL, 0)
            n_issued = 1

        def start_service(replica: Replica, now: float) -> None:
            req: SimRequest = replica.queue.popleft()
            # A speculatively-routed request (lookahead batching) may be
            # popped before its uplink completes; service cannot start
            # before the input is on the server.  No-op without lookahead.
            now = max(now, req.enqueue_ms)
            req.service_start_ms = now
            store.observe_queue(req.model, req.queue_wait_ms)
            req.service_ms = svc.sample(rng, req.model, replica.speed)
            replica.current = req
            replica.busy_until = now + req.service_ms
            evq.push(now + req.service_ms, FINISH, (replica, req))

        def issue_next_closed_loop(now: float) -> None:
            nonlocal n_issued
            if arrivals.closed_loop and n_issued < n_requests:
                evq.push(arrivals.next_after(rng, now, n_issued),
                         ARRIVAL, n_issued)
                n_issued += 1

        while evq:
            ev = evq.pop()
            now = ev.time

            if ev.kind == ARRIVAL:
                req = SimRequest(rid=ev.data, arrival_ms=now)
                req.t_sla_ms = float(sla_for(ev.data)) if sla_for else t_sla
                req.sla_class = str(class_for(ev.data)) if class_for else ""
                req.t_input_ms = float(self.network.sample(rng, 1)[0])
                evq.push(now + req.t_input_ms, ENQUEUE, req)
                if not arrivals.closed_loop and n_issued < n_requests:
                    t_next = arrivals.next_after(rng, now, n_issued)
                    if t_next is not None:
                        evq.push(t_next, ARRIVAL, n_issued)
                        n_issued += 1

            elif ev.kind == ENQUEUE:
                # Group consecutive ENQUEUEs inside the batching window
                # into ONE route_batch call (vectorized selection).
                ev.data.enqueue_ms = now
                batch: List[SimRequest] = [ev.data]
                limit = now + self.batch_window_ms
                while evq:
                    head = evq.peek()
                    if head.kind != ENQUEUE or head.time > limit:
                        break
                    nxt = evq.pop()
                    nxt.data.enqueue_ms = nxt.time
                    batch.append(nxt.data)
                decisions = router.route_batch(
                    [InferenceRequest(rid=r.rid, arrival_ms=r.arrival_ms,
                                      t_sla_ms=r.t_sla_ms,
                                      t_input_ms=r.t_input_ms,
                                      sla_class=r.sla_class or None)
                     for r in batch],
                    rng,
                    w_queue_fn=lambda m: self.pool.queue_wait(m, now, store),
                    depth_fn=lambda m: min(r.depth() for r in
                                           self.pool.candidates(m)))
                for req, dec in zip(batch, decisions):
                    if not dec.admitted:
                        # Router-side shed: no selection spent, no
                        # replica touched.
                        req.rejected = True
                        req.reject_reason = dec.reject_reason
                        req.depart_ms = req.enqueue_ms
                        rejected.append(req)
                        issue_next_closed_loop(now)
                        continue
                    req.model = dec.variant
                    req.fallback = dec.fallback
                    replica = self.pool.best_for(req.model, now, store)
                    req.replica = replica.name
                    if replica.full():
                        req.rejected = True
                        req.reject_reason = "replica queue full"
                        # == now without lookahead; a speculatively-routed
                        # request cannot depart before its own enqueue.
                        req.depart_ms = max(now, req.enqueue_ms)
                        rejected.append(req)
                        issue_next_closed_loop(now)
                        continue
                    replica.queue.append(req)
                    replica.peak_depth = max(replica.peak_depth,
                                             replica.depth())
                    if replica.current is None:
                        start_service(replica, now)

            elif ev.kind == FINISH:
                replica, req = ev.data
                req.finish_ms = now
                replica.current = None
                replica.n_served += 1
                replica.busy_ms += req.service_ms
                store.observe(req.model, req.service_ms)
                # Cold-model refresh (§3.3): probe one stale model
                # out-of-band, as in the original closed loop.
                if self.cold_probe:
                    cold = store.cold_models()
                    if cold:
                        probe = cold[int(rng.integers(len(cold)))]
                        store.observe(probe, svc.sample(rng, probe))
                        store.profiles[probe].last_selected = store.step
                evq.push(now + req.t_input_ms, DEPART, req)
                if replica.queue:
                    start_service(replica, now)

            elif ev.kind == DEPART:
                req = ev.data
                req.depart_ms = now
                completed.append(req)
                if arrivals.closed_loop and n_issued < n_requests:
                    evq.push(arrivals.next_after(rng, now, n_issued),
                             ARRIVAL, n_issued)
                    n_issued += 1

        # Per-run request records stay inspectable (per-SLA-class slicing
        # in tests and frontier studies reads them after run()).
        self.completed_requests = completed
        self.rejected_requests = rejected
        return self._summarise(router.name, t_sla, truth, completed, rejected)

    # ------------------------------------------------------------------
    # SoA record-array summary: one pass packs the per-request fields
    # into contiguous columns; every statistic below is a vectorized
    # reduction instead of a Python list comprehension per metric.
    _REQ_DTYPE = np.dtype([("t_input", "f8"), ("wait", "f8"),
                           ("service", "f8"), ("arrival", "f8"),
                           ("depart", "f8"), ("t_sla", "f8"),
                           ("model", "i4")])

    def _summarise(self, policy_name, t_sla, truth, completed, rejected
                   ) -> LoadSimResult:
        n_arrived = len(completed) + len(rejected)
        if not completed:
            return LoadSimResult(
                policy=policy_name, t_sla=t_sla,
                n_arrived=n_arrived, n_completed=0, n_rejected=len(rejected),
                sla_attainment=0.0, mean_accuracy=0.0, mean_latency=0.0,
                p50_latency=0.0, p99_latency=0.0, mean_queue_wait=0.0,
                p99_queue_wait=0.0, peak_queue_depth=0, model_usage={},
                replica_utilization={},
                per_class=self._per_class(completed, rejected, {}))
        model_ids = {name: i for i, name in enumerate(truth)}
        rec = np.fromiter(
            ((r.t_input_ms, r.queue_wait_ms, r.service_ms, r.arrival_ms,
              r.depart_ms, r.t_sla_ms, model_ids[r.model])
             for r in completed),
            dtype=self._REQ_DTYPE, count=len(completed))
        # Component sum, identical to SimRequest.e2e_ms per element.
        e2e = 2.0 * rec["t_input"] + rec["wait"] + rec["service"]
        # Scored against each request's own SLA (identical to the scalar
        # comparison when every request carries the run-level t_sla).
        met = int((e2e <= rec["t_sla"]).sum())
        acc_by_id = np.array([e.top1 / 100.0 for e in truth.values()])
        counts = np.bincount(rec["model"], minlength=len(model_ids))
        usage = {name: int(counts[i]) for name, i in model_ids.items()
                 if counts[i]}
        # Horizon spans *every* request the pool saw — rejected ones
        # included, so utilization is not inflated under heavy shedding
        # (a shed request still occupies wall-clock on the timeline).
        first = float(rec["arrival"].min())
        last = float(rec["depart"].max())
        if rejected:
            first = min(first, min(r.arrival_ms for r in rejected))
            last = max(last, max(r.depart_ms for r in rejected))
        horizon = max(last - first, 1e-9)
        return LoadSimResult(
            policy=policy_name, t_sla=t_sla,
            n_arrived=n_arrived, n_completed=len(completed),
            n_rejected=len(rejected),
            sla_attainment=met / max(n_arrived, 1),
            mean_accuracy=float(np.mean(acc_by_id[rec["model"]])),
            mean_latency=float(e2e.mean()),
            p50_latency=float(np.percentile(e2e, 50)),
            p99_latency=float(np.percentile(e2e, 99)),
            mean_queue_wait=float(rec["wait"].mean()),
            p99_queue_wait=float(np.percentile(rec["wait"], 99)),
            peak_queue_depth=max(r.peak_depth for r in self.pool.replicas),
            model_usage={k: v / len(completed)
                         for k, v in sorted(usage.items())},
            replica_utilization={r.name: r.busy_ms / horizon
                                 for r in self.pool.replicas},
            horizon_ms=horizon,
            per_class=self._per_class(
                completed, rejected,
                {name: e.top1 / 100.0 for name, e in truth.items()}))

    @staticmethod
    def _per_class(completed, rejected, acc_of) -> Dict[str, Dict[str, float]]:
        """Class-sliced attainment/accuracy/shed rows; {} when no request
        carried a class label (the common single-class run)."""
        if not any(r.sla_class for r in completed) and \
                not any(r.sla_class for r in rejected):
            return {}
        out: Dict[str, Dict[str, float]] = {}
        classes = sorted({r.sla_class for r in completed}
                         | {r.sla_class for r in rejected})
        for cls in classes:
            done = [r for r in completed if r.sla_class == cls]
            shed = [r for r in rejected if r.sla_class == cls]
            n = len(done) + len(shed)
            met = sum(r.e2e_ms <= r.t_sla_ms for r in done)
            out[cls or "default"] = {
                "n_arrived": n,
                "n_rejected": len(shed),
                "shed_rate": len(shed) / max(n, 1),
                "attainment": met / max(n, 1),
                "accuracy": (float(np.mean([acc_of[r.model] for r in done]))
                             if done else 0.0),
                "mean_latency": (float(np.mean([r.e2e_ms for r in done]))
                                 if done else 0.0),
            }
        return out


def rate_sweep(sim: ServingSimulator, policy_fn, rates_rps: Sequence[float],
               t_sla: float, n_requests: int = 2000) -> List[LoadSimResult]:
    """Arrival-rate sweep: SLA attainment vs offered load.

    ``policy_fn()`` builds a fresh policy per point (stateful policies
    like ``StaticGreedy`` must not leak across runs)."""
    from repro.sim.arrivals import PoissonArrivals
    return [sim.run(policy_fn(), t_sla, n_requests,
                    arrivals=PoissonArrivals(rate))
            for rate in rates_rps]
