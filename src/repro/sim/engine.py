"""The discrete-event serving simulator.

Request lifecycle (all times ms):

    ARRIVAL ── uplink (T_input) ──▶ ENQUEUE ── FIFO wait ──▶ service
            ── inference ──▶ FINISH ── downlink (T_input) ──▶ DEPART

At ENQUEUE the policy selects a model (queue-aware mode presents the
policy with per-model budgets ``T_sla - 2*T_input - W_queue(m)`` via
``queueaware.shifted_store``), the request joins the FIFO of the
least-loaded capable replica, and — exactly like the live serving path —
the profile store receives the *inference* latency at FINISH and the
observed queue wait at service start (telemetry mirroring
``serving/batcher.py``).

Driven by ``ClosedLoopArrivals`` over a single shared replica this
engine replays the paper's §4 closed loop draw-for-draw —
``core/simulate.Simulator`` is now a thin wrapper around it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from repro.core.netmodel import NetworkModel
from repro.core.policy import Policy, budget
from repro.core.profiles import ProfileStore
from repro.core.zoo import ZooEntry, make_store, true_profiles
from repro.sim.arrivals import ArrivalProcess, ClosedLoopArrivals
from repro.sim.events import ARRIVAL, DEPART, ENQUEUE, FINISH, EventQueue
from repro.sim.queueaware import QueueAwareSelector
from repro.sim.replica import (GaussianServiceModel, Replica, ReplicaPool,
                               shared_replicas)


@dataclass
class SimRequest:
    rid: int
    arrival_ms: float
    t_input_ms: float = 0.0
    model: str = ""
    replica: str = ""
    fallback: bool = False
    rejected: bool = False
    enqueue_ms: float = 0.0
    service_start_ms: float = 0.0
    service_ms: float = 0.0
    finish_ms: float = 0.0
    depart_ms: float = 0.0

    @property
    def queue_wait_ms(self) -> float:
        return self.service_start_ms - self.enqueue_ms

    @property
    def e2e_ms(self) -> float:
        # Component sum (not event-time subtraction): uplink + FIFO wait
        # + inference + downlink.  Bit-identical to the legacy closed
        # loop's ``2*T_input + T_inf`` at zero queue wait.
        return 2.0 * self.t_input_ms + self.queue_wait_ms + self.service_ms


@dataclass
class LoadSimResult:
    policy: str
    t_sla: float
    n_arrived: int
    n_completed: int
    n_rejected: int
    sla_attainment: float        # met / arrived (rejections are misses)
    mean_accuracy: float         # over completed requests
    mean_latency: float          # e2e ms over completed
    p50_latency: float
    p99_latency: float
    mean_queue_wait: float
    p99_queue_wait: float
    peak_queue_depth: int
    model_usage: Dict[str, float]          # fraction of completed
    replica_utilization: Dict[str, float]  # busy time / horizon
    horizon_ms: float = 0.0

    @property
    def violation_rate(self) -> float:
        return 1.0 - self.sla_attainment


class ServingSimulator:
    """Event-driven serving over a pool of heterogeneous replicas."""

    def __init__(self, entries: Sequence[ZooEntry], network: NetworkModel,
                 replicas: Optional[Union[ReplicaPool, List[Replica]]] = None,
                 *, seed: int = 0, alpha: float = 0.1, cold_age: int = 500,
                 cold_probe: bool = True, spike_prob: float = 0.0,
                 spike_mult: float = 10.0, queue_aware: bool = False):
        self.entries = list(entries)
        self.network = network
        if replicas is None:
            replicas = shared_replicas(1)
        self.pool = (replicas if isinstance(replicas, ReplicaPool)
                     else ReplicaPool(replicas))
        self.seed = seed
        self.alpha = alpha
        self.cold_age = cold_age
        self.cold_probe = cold_probe
        self.spike_prob = spike_prob
        self.spike_mult = spike_mult
        self.queue_aware = queue_aware

    # ------------------------------------------------------------------
    def run(self, policy: Policy, t_sla: float,
            n_requests: int = 10_000,
            arrivals: Optional[ArrivalProcess] = None,
            warm: bool = True,
            store: Optional[ProfileStore] = None) -> LoadSimResult:
        arrivals = arrivals or ClosedLoopArrivals()
        rng = np.random.default_rng(self.seed)
        store = store or make_store(self.entries, alpha=self.alpha,
                                    cold_age=self.cold_age, warm=warm)
        truth = true_profiles(self.entries)
        svc = GaussianServiceModel(truth, spike_prob=self.spike_prob,
                                   spike_mult=self.spike_mult)
        selector = QueueAwareSelector(policy) if self.queue_aware else None
        self.pool.reset()

        evq = EventQueue()
        completed: List[SimRequest] = []
        rejected: List[SimRequest] = []
        n_issued = 0
        if n_requests > 0:
            evq.push(arrivals.first(rng), ARRIVAL, 0)
            n_issued = 1

        def start_service(replica: Replica, now: float) -> None:
            req: SimRequest = replica.queue.popleft()
            req.service_start_ms = now
            store.observe_queue(req.model, req.queue_wait_ms)
            req.service_ms = svc.sample(rng, req.model, replica.speed)
            replica.current = req
            replica.busy_until = now + req.service_ms
            evq.push(now + req.service_ms, FINISH, (replica, req))

        while evq:
            ev = evq.pop()
            now = ev.time

            if ev.kind == ARRIVAL:
                req = SimRequest(rid=ev.data, arrival_ms=now)
                req.t_input_ms = float(self.network.sample(rng, 1)[0])
                evq.push(now + req.t_input_ms, ENQUEUE, req)
                if not arrivals.closed_loop and n_issued < n_requests:
                    t_next = arrivals.next_after(rng, now, n_issued)
                    if t_next is not None:
                        evq.push(t_next, ARRIVAL, n_issued)
                        n_issued += 1

            elif ev.kind == ENQUEUE:
                req = ev.data
                req.enqueue_ms = now
                t_budget = budget(t_sla, req.t_input_ms)
                if selector is not None:
                    trace = selector.select_traced(
                        store, t_budget,
                        lambda m: self.pool.queue_wait(m, now, store), rng)
                else:
                    trace = policy.select_traced(store, t_budget, rng)
                req.model = trace.chosen
                req.fallback = trace.fallback
                store.mark_selected(req.model)
                replica = self.pool.best_for(req.model, now, store)
                req.replica = replica.name
                if replica.full():
                    req.rejected = True
                    req.depart_ms = now
                    rejected.append(req)
                    if arrivals.closed_loop and n_issued < n_requests:
                        evq.push(arrivals.next_after(rng, now, n_issued),
                                 ARRIVAL, n_issued)
                        n_issued += 1
                    continue
                replica.queue.append(req)
                replica.peak_depth = max(replica.peak_depth, replica.depth())
                if replica.current is None:
                    start_service(replica, now)

            elif ev.kind == FINISH:
                replica, req = ev.data
                req.finish_ms = now
                replica.current = None
                replica.n_served += 1
                replica.busy_ms += req.service_ms
                store.observe(req.model, req.service_ms)
                # Cold-model refresh (§3.3): probe one stale model
                # out-of-band, as in the original closed loop.
                if self.cold_probe:
                    cold = store.cold_models()
                    if cold:
                        probe = cold[int(rng.integers(len(cold)))]
                        store.observe(probe, svc.sample(rng, probe))
                        store.profiles[probe].last_selected = store.step
                evq.push(now + req.t_input_ms, DEPART, req)
                if replica.queue:
                    start_service(replica, now)

            elif ev.kind == DEPART:
                req = ev.data
                req.depart_ms = now
                completed.append(req)
                if arrivals.closed_loop and n_issued < n_requests:
                    evq.push(arrivals.next_after(rng, now, n_issued),
                             ARRIVAL, n_issued)
                    n_issued += 1

        name = selector.name if selector is not None else \
            getattr(policy, "name", str(policy))
        return self._summarise(name, t_sla, truth, completed, rejected)

    # ------------------------------------------------------------------
    # SoA record-array summary: one pass packs the per-request fields
    # into contiguous columns; every statistic below is a vectorized
    # reduction instead of a Python list comprehension per metric.
    _REQ_DTYPE = np.dtype([("t_input", "f8"), ("wait", "f8"),
                           ("service", "f8"), ("arrival", "f8"),
                           ("depart", "f8"), ("model", "i4")])

    def _summarise(self, policy_name, t_sla, truth, completed, rejected
                   ) -> LoadSimResult:
        n_arrived = len(completed) + len(rejected)
        if not completed:
            return LoadSimResult(
                policy=policy_name, t_sla=t_sla,
                n_arrived=n_arrived, n_completed=0, n_rejected=len(rejected),
                sla_attainment=0.0, mean_accuracy=0.0, mean_latency=0.0,
                p50_latency=0.0, p99_latency=0.0, mean_queue_wait=0.0,
                p99_queue_wait=0.0, peak_queue_depth=0, model_usage={},
                replica_utilization={})
        model_ids = {name: i for i, name in enumerate(truth)}
        rec = np.fromiter(
            ((r.t_input_ms, r.queue_wait_ms, r.service_ms, r.arrival_ms,
              r.depart_ms, model_ids[r.model]) for r in completed),
            dtype=self._REQ_DTYPE, count=len(completed))
        # Component sum, identical to SimRequest.e2e_ms per element.
        e2e = 2.0 * rec["t_input"] + rec["wait"] + rec["service"]
        met = int((e2e <= t_sla).sum())
        acc_by_id = np.array([e.top1 / 100.0 for e in truth.values()])
        counts = np.bincount(rec["model"], minlength=len(model_ids))
        usage = {name: int(counts[i]) for name, i in model_ids.items()
                 if counts[i]}
        # Horizon spans *every* request the pool saw — rejected ones
        # included, so utilization is not inflated under heavy shedding
        # (a shed request still occupies wall-clock on the timeline).
        first = float(rec["arrival"].min())
        last = float(rec["depart"].max())
        if rejected:
            first = min(first, min(r.arrival_ms for r in rejected))
            last = max(last, max(r.depart_ms for r in rejected))
        horizon = max(last - first, 1e-9)
        return LoadSimResult(
            policy=policy_name, t_sla=t_sla,
            n_arrived=n_arrived, n_completed=len(completed),
            n_rejected=len(rejected),
            sla_attainment=met / max(n_arrived, 1),
            mean_accuracy=float(np.mean(acc_by_id[rec["model"]])),
            mean_latency=float(e2e.mean()),
            p50_latency=float(np.percentile(e2e, 50)),
            p99_latency=float(np.percentile(e2e, 99)),
            mean_queue_wait=float(rec["wait"].mean()),
            p99_queue_wait=float(np.percentile(rec["wait"], 99)),
            peak_queue_depth=max(r.peak_depth for r in self.pool.replicas),
            model_usage={k: v / len(completed)
                         for k, v in sorted(usage.items())},
            replica_utilization={r.name: r.busy_ms / horizon
                                 for r in self.pool.replicas},
            horizon_ms=horizon)


def rate_sweep(sim: ServingSimulator, policy_fn, rates_rps: Sequence[float],
               t_sla: float, n_requests: int = 2000) -> List[LoadSimResult]:
    """Arrival-rate sweep: SLA attainment vs offered load.

    ``policy_fn()`` builds a fresh policy per point (stateful policies
    like ``StaticGreedy`` must not leak across runs)."""
    from repro.sim.arrivals import PoissonArrivals
    return [sim.run(policy_fn(), t_sla, n_requests,
                    arrivals=PoissonArrivals(rate))
            for rate in rates_rps]
