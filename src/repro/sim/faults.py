"""Fault injection: environment events scheduled on the event queue.

The engine's world was fair-weather — replicas never failed, the
ground-truth latency process never moved, and the network stayed at its
seeded distribution.  These records describe the three ways it can now
misbehave mid-run, each scheduled as a ``FAULT`` event on the same
:class:`~repro.sim.events.EventQueue` that drives the request lifecycle
(so faults interleave deterministically with traffic under a seed):

- :class:`ReplicaFault` — replica lifecycle: ``kill`` (drop in-flight +
  queued work, stop accepting; the engine re-routes the victims through
  the router's retry path), ``degrade`` (slow by ``factor``; keeps
  serving), ``drain`` (no new work, finish the queue), ``recover``
  (back to full speed, accepting).
- :class:`LatencyDrift` — the ground-truth service process for one
  model shifts: μ/σ multiplied (absolute vs the seeded truth, not
  cumulative — a later ``mu_mult=1.0`` event is the recovery).
- :class:`NetworkDrift` — the uplink/downlink RTT scales by
  ``rtt_mult`` (absolute vs the seeded network model).

None of these records touches the RNG; a run with no faults configured
schedules no events and is bit-identical to the pre-fault engine.
The declarative layer (``scenario/spec.py`` ``FaultSpec``/``DriftSpec``)
compiles down to these via ``scenario.build.build_faults``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Union

from repro.sim.events import FAULT, EventQueue

REPLICA_FAULT_KINDS = ("kill", "degrade", "drain", "recover")


@dataclass(frozen=True)
class ReplicaFault:
    """One replica-lifecycle transition at ``at_ms``."""
    at_ms: float
    kind: str                # kill | degrade | drain | recover
    replica: str             # replica name, e.g. "r0" or "InceptionV3/0"
    factor: float = 2.0      # degrade slowdown: speed -> base_speed/factor

    def __post_init__(self):
        if self.kind not in REPLICA_FAULT_KINDS:
            raise ValueError(f"kind must be one of {REPLICA_FAULT_KINDS}, "
                             f"got {self.kind!r}")
        if not self.replica:
            raise ValueError("ReplicaFault needs a replica name")
        if self.at_ms < 0.0:
            raise ValueError("at_ms must be non-negative")
        if self.factor <= 0.0:
            raise ValueError("factor must be positive")


@dataclass(frozen=True)
class LatencyDrift:
    """The true service-latency process of ``model`` shifts at
    ``at_ms``: multipliers are absolute vs the seeded (μ, σ)."""
    at_ms: float
    model: str
    mu_mult: float = 1.0
    sigma_mult: float = 1.0

    def __post_init__(self):
        if not self.model:
            raise ValueError("LatencyDrift needs a model name")
        if self.at_ms < 0.0:
            raise ValueError("at_ms must be non-negative")
        if self.mu_mult <= 0.0 or self.sigma_mult <= 0.0:
            raise ValueError("mu_mult/sigma_mult must be positive")


@dataclass(frozen=True)
class NetworkDrift:
    """The uplink/downlink transfer time scales by ``rtt_mult`` at
    ``at_ms`` (absolute vs the seeded network model)."""
    at_ms: float
    rtt_mult: float = 1.0

    def __post_init__(self):
        if self.at_ms < 0.0:
            raise ValueError("at_ms must be non-negative")
        if self.rtt_mult <= 0.0:
            raise ValueError("rtt_mult must be positive")


FaultEvent = Union[ReplicaFault, LatencyDrift, NetworkDrift]


def schedule_faults(evq: EventQueue,
                    faults: Iterable[FaultEvent]) -> int:
    """Push every fault record as a ``FAULT`` event at its ``at_ms``.
    Returns the number scheduled (0 leaves the queue untouched — the
    no-fault run stays bit-identical)."""
    n = 0
    for f in faults:
        evq.push(f.at_ms, FAULT, f)
        n += 1
    return n
