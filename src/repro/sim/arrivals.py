"""Arrival processes for the serving simulator.

Three regimes:

- ``ClosedLoopArrivals``: one outstanding request; the next request is
  issued when the previous response departs (plus optional think time).
  This is the paper's §4 evaluation loop — ``core/simulate.py`` is the
  single-replica instance of the engine driven by this process.
- ``PoissonArrivals``: open-loop memoryless traffic at a target rate —
  the production regime where queueing delay appears (MDInference's
  dominant latency source).
- ``TraceArrivals``: replay an explicit list of arrival timestamps
  (e.g. from a production trace or a synthetic burst pattern).

Open-loop processes chain: handling arrival *i* schedules arrival
*i+1*.  Closed-loop chains off request departure instead, so it never
draws from the RNG and preserves the exact draw order of the original
closed loop.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np


class ArrivalProcess:
    closed_loop: bool = False

    def first(self, rng: np.random.Generator) -> float:
        """Time of the first arrival (ms)."""
        return 0.0

    def next_after(self, rng: np.random.Generator, t: float,
                   n_issued: int) -> Optional[float]:
        """Time of the next arrival given the previous chain point ``t``
        (the previous *arrival* for open-loop, the previous *departure*
        for closed-loop).  ``None`` means the process is exhausted."""
        raise NotImplementedError


@dataclass
class ClosedLoopArrivals(ArrivalProcess):
    """Sequential issue: next request when the previous one departs."""
    think_ms: float = 0.0

    def __post_init__(self):
        self.closed_loop = True

    def next_after(self, rng, t, n_issued):
        return t + self.think_ms


@dataclass
class PoissonArrivals(ArrivalProcess):
    """Open-loop Poisson traffic at ``rate_rps`` requests per second."""
    rate_rps: float

    def __post_init__(self):
        assert self.rate_rps > 0.0
        self._gap_ms = 1000.0 / self.rate_rps

    def first(self, rng):
        return float(rng.exponential(self._gap_ms))

    def next_after(self, rng, t, n_issued):
        return t + float(rng.exponential(self._gap_ms))


@dataclass
class TraceArrivals(ArrivalProcess):
    """Replay explicit arrival timestamps (ms, non-decreasing).

    Timestamps are validated at construction — finite, non-negative and
    sorted — so a malformed trace fails loudly here instead of silently
    producing negative inter-arrivals (events scheduled in the past)
    deep inside the event loop.  Duplicate timestamps are legal: they
    model simultaneous arrivals.  Note the engine batches *ENQUEUE*
    events (arrival + sampled uplink), so duplicates reach one
    ``route_batch`` call only over a zero-jitter network — under jitter,
    set ``batch_window_ms`` to at least the uplink spread to group them.
    """
    times_ms: Sequence[float]

    def __post_init__(self):
        times = np.asarray(self.times_ms, dtype=np.float64)
        if times.size == 0:
            raise ValueError("TraceArrivals needs at least one timestamp")
        if not np.isfinite(times).all():
            raise ValueError("TraceArrivals timestamps must be finite "
                             "(got NaN or inf)")
        if times[0] < 0.0:
            raise ValueError("TraceArrivals timestamps must be "
                             f"non-negative (first is {times[0]!r})")
        gaps = np.diff(times)
        if gaps.size and gaps.min() < 0.0:
            i = int(np.argmin(gaps)) + 1
            raise ValueError(
                "TraceArrivals timestamps must be sorted ascending: "
                f"times_ms[{i}]={times[i]!r} < times_ms[{i-1}]={times[i-1]!r}")

    def first(self, rng):
        return float(self.times_ms[0])

    def next_after(self, rng, t, n_issued):
        if n_issued >= len(self.times_ms):
            return None
        return float(self.times_ms[n_issued])

    def __len__(self) -> int:
        return len(self.times_ms)


# ----------------------------------------------------------------------
# Trace synthesizers: non-homogeneous Poisson processes rendered to
# explicit timestamps (so they replay through ``TraceArrivals`` and its
# construction-time validation).  Both use Lewis–Shedler thinning:
# candidate arrivals are drawn at the peak rate and kept with
# probability rate(t)/rate_peak, which is exact for any bounded rate
# function.  Deterministic given ``seed``.
# ----------------------------------------------------------------------

def _thin(n: int, rate_peak_rps: float, rate_at, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    gap_ms = 1000.0 / rate_peak_rps
    out = np.empty(n, dtype=np.float64)
    t, k = 0.0, 0
    while k < n:
        t += float(rng.exponential(gap_ms))
        if rng.random() * rate_peak_rps <= rate_at(t):
            out[k] = t
            k += 1
    return out


def diurnal_trace(n: int, base_rate_rps: float, *,
                  period_ms: float = 60_000.0, amplitude: float = 0.8,
                  phase: float = 0.0, seed: int = 0) -> TraceArrivals:
    """Sinusoidal day/night load: ``rate(t) = base · (1 + amplitude ·
    sin(2πt/period + phase))``.  ``amplitude ∈ [0, 1)`` keeps the rate
    positive; one ``period_ms`` is one synthetic "day"."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    if base_rate_rps <= 0.0 or period_ms <= 0.0:
        raise ValueError("base_rate_rps and period_ms must be positive")
    rate = lambda t: base_rate_rps * (
        1.0 + amplitude * np.sin(2.0 * np.pi * t / period_ms + phase))
    return TraceArrivals(_thin(n, base_rate_rps * (1.0 + amplitude),
                               rate, seed))


def rate_trace_arrivals(counts, *, n: int, rate_rps: float,
                        period_ms: float = 86_400_000.0,
                        phase: float = 0.0, seed: int = 0) -> TraceArrivals:
    """Replay a *rate* trace (per-interval request counts — the shape
    Azure Functions publishes) as explicit arrival timestamps.

    ``counts`` (K,) is normalized to a piecewise-constant rate profile
    over one cyclic ``period_ms`` "day" scaled so the *mean* rate is
    ``rate_rps``, then thinned (Lewis–Shedler, like the synthesizers)
    into ``n`` timestamps.  ``phase`` ∈ [0, 1) rotates the profile by a
    fraction of the day — the fleet's time-zone offset: the same real
    trace shape peaks at a different simulated hour in every cell.
    Deterministic given ``seed``."""
    c = np.asarray(counts, dtype=np.float64)
    if c.ndim != 1 or c.size < 2:
        raise ValueError("rate trace needs a 1-D array of >= 2 counts")
    if not np.isfinite(c).all() or (c < 0).any():
        raise ValueError("rate-trace counts must be finite and >= 0")
    if c.sum() <= 0.0:
        raise ValueError("rate trace is all-zero")
    if not 0.0 <= phase < 1.0:
        raise ValueError(f"phase must be in [0, 1), got {phase}")
    if rate_rps <= 0.0 or period_ms <= 0.0 or n <= 0:
        raise ValueError("need rate_rps > 0, period_ms > 0, n > 0")
    shape = c / c.mean()                  # mean-1 profile
    K = shape.size
    off = phase * K

    def rate(t):
        k = int((t / period_ms * K + off) % K)
        return rate_rps * shape[k]

    return TraceArrivals(_thin(n, rate_rps * float(shape.max()),
                               rate, seed))


def load_rate_counts(path) -> np.ndarray:
    """Parse a rate trace file into per-interval counts.

    Accepted shapes (all real-world-trace friendly):

    - **Azure-Functions CSV**: header rows with hash/trigger columns
      followed by per-minute count columns ``1..1440`` — counts are
      summed across functions per minute;
    - **two-column CSV** ``interval,count`` (header optional);
    - **one-column CSV**: one count per line;
    - **JSON**: ``{"counts": [...]}`` or a bare list.
    """
    import json as _json
    p = str(path)
    if p.endswith(".json"):
        with open(p, "r", encoding="utf-8") as f:
            d = _json.load(f)
        return np.asarray(d["counts"] if isinstance(d, dict) else d,
                          dtype=np.float64)
    import csv
    with open(p, "r", encoding="utf-8", newline="") as f:
        rows = [r for r in csv.reader(f) if r and any(x.strip() for x in r)]
    if not rows:
        raise ValueError(f"empty rate trace file: {p}")

    def _num(x):
        try:
            return float(x)
        except ValueError:
            return None

    header = [_num(x) for x in rows[0]]
    if any(v is None for v in header):
        # Header row: Azure format when >= 2 numeric-named columns
        # (the per-minute "1".."1440" axis); else "interval,count".
        minute_cols = [i for i, v in enumerate(header) if v is not None]
        if len(minute_cols) >= 2:
            body = rows[1:]
            out = np.zeros(len(minute_cols), dtype=np.float64)
            for r in body:
                for j, i in enumerate(minute_cols):
                    v = _num(r[i]) if i < len(r) else None
                    out[j] += v if v is not None else 0.0
            return out
        rows = rows[1:]
        if not rows:
            raise ValueError(f"rate trace {p} has a header but no data")
    if len(rows[0]) >= 2:
        return np.asarray([float(r[1]) for r in rows], dtype=np.float64)
    return np.asarray([float(r[0]) for r in rows], dtype=np.float64)


def load_trace(path, *, n: int, rate_rps: float,
               period_ms: float = 86_400_000.0, phase: float = 0.0,
               seed: int = 0) -> TraceArrivals:
    """Real-trace replay: parse an Azure-Functions-style CSV/JSON rate
    trace (``load_rate_counts``) and render it to ``n`` arrival
    timestamps at mean ``rate_rps`` over a ``period_ms`` day
    (``rate_trace_arrivals``).  The ``fleet_diurnal`` scenario feeds
    every cell the same file with a per-cell ``phase``, so diurnal load
    rolling across time zones comes from a recorded shape instead of
    the sinusoid synthesizer."""
    return rate_trace_arrivals(load_rate_counts(path), n=n,
                               rate_rps=rate_rps, period_ms=period_ms,
                               phase=phase, seed=seed)


def burst_trace(n: int, base_rate_rps: float, *, burst_rate_rps: float,
                burst_every_ms: float = 10_000.0,
                burst_len_ms: float = 1_000.0,
                seed: int = 0) -> TraceArrivals:
    """Square-wave load: quiet traffic at ``base_rate_rps`` punctuated by
    a ``burst_len_ms`` burst at ``burst_rate_rps`` every
    ``burst_every_ms`` (the flash-crowd / retry-storm shape admission
    control is for)."""
    if base_rate_rps <= 0.0 or burst_rate_rps < base_rate_rps:
        raise ValueError("need 0 < base_rate_rps <= burst_rate_rps")
    if not 0.0 < burst_len_ms <= burst_every_ms:
        raise ValueError("need 0 < burst_len_ms <= burst_every_ms")
    rate = lambda t: (burst_rate_rps
                      if (t % burst_every_ms) < burst_len_ms
                      else base_rate_rps)
    return TraceArrivals(_thin(n, burst_rate_rps, rate, seed))
